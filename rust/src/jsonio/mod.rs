//! Minimal JSON substrate (parser + writer).
//!
//! The offline build environment carries no serde/serde_json (DESIGN.md §3),
//! so the AOT manifest, config files, and metrics exports go through this
//! self-contained implementation: a recursive-descent parser over the full
//! JSON grammar (RFC 8259) and an escaping writer. Numbers are f64 (the
//! manifest only holds shapes/counts well inside 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// insertion order is not semantic; BTreeMap gives stable output
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(map) => map
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?} in object")),
            _ => bail!("expected object while reading key {key:?}"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("expected non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let v = self.as_f64()?;
        if v.fract() != 0.0 {
            bail!("expected integer, got {v}");
        }
        Ok(v as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// `[1, 2, 3]` -> `Vec<usize>` (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// `[1.0, 0.5]` -> `Vec<f64>` (per-client scale lists in scenario
    /// trace files).
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ----------------------------------------------------------- builders

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    // ------------------------------------------------------------- writer

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    /// The canonical byte form used for content hashing (the experiment
    /// service's result-cache keys): semantically equal values serialize to
    /// identical bytes. The guarantee rests on two properties of this
    /// module — objects are `BTreeMap`s (key order is sorted, never
    /// insertion order), and the compact writer emits exactly one spelling
    /// per value (no whitespace; integral f64 below 2^53 as integer text).
    /// Today that makes it an alias of [`Json::to_string_compact`]; cache
    /// keys must go through THIS name so the contract survives any future
    /// pretty/compact formatting change.
    pub fn to_canonical_string(&self) -> String {
        self.to_string_compact()
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if v.fract() == 0.0 && v.abs() < 9e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------- parser

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value().context("parsing JSON")?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input at byte {}", self.pos))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}, found {:?}", b as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let v: f64 = text
            .parse()
            .with_context(|| format!("invalid number {text:?} at byte {start}"))?;
        Ok(Json::Num(v))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let low =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                code
                            };
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| anyhow!("invalid codepoint {c:#x}"))?,
                            );
                        }
                        other => bail!("invalid escape \\{}", other as char),
                    }
                }
                _ => {
                    // collect the full utf8 sequence starting at pos-1
                    let start = self.pos - 1;
                    let len = utf8_len(b)?;
                    let end = start + len;
                    if end > self.bytes.len() {
                        bail!("truncated utf8 sequence");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7f => Ok(1),
        0xc0..=0xdf => Ok(2),
        0xe0..=0xef => Ok(3),
        0xf0..=0xf7 => Ok(4),
        _ => bail!("invalid utf8 lead byte {first:#x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "presets": {"commag": {"batch": 32, "eta_c": 0.05,
            "server_layers": [{"d_in": 64, "act": true, "z_index": -1}]}},
          "artifacts": {"a": {"file": "a.hlo.txt", "inputs": [[32, 64], [1]]}}
        }"#;
        let j = Json::parse(doc).unwrap();
        let p = j.get("presets").unwrap().get("commag").unwrap();
        assert_eq!(p.get("batch").unwrap().as_usize().unwrap(), 32);
        assert!((p.get("eta_c").unwrap().as_f64().unwrap() - 0.05).abs() < 1e-12);
        let layer = &p.get("server_layers").unwrap().as_arr().unwrap()[0];
        assert_eq!(layer.get("z_index").unwrap().as_i64().unwrap(), -1);
        assert!(layer.get("act").unwrap().as_bool().unwrap());
        let inputs = j
            .get("artifacts").unwrap()
            .get("a").unwrap()
            .get("inputs").unwrap();
        assert_eq!(inputs.as_arr().unwrap()[0].as_usize_vec().unwrap(), vec![32, 64]);
    }

    #[test]
    fn f64_vec_accessor() {
        let j = Json::parse("[1, 0.5, 3.25]").unwrap();
        assert_eq!(j.as_f64_vec().unwrap(), vec![1.0, 0.5, 3.25]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f64_vec().is_err());
        assert!(Json::parse("1").unwrap().as_f64_vec().is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("name", Json::str("split\"me\n")),
            ("vals", Json::arr(vec![Json::num(1.0), Json::num(-2.5), Json::Null])),
            ("ok", Json::Bool(true)),
        ]);
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, v, "{text}");
        }
    }

    #[test]
    fn number_forms() {
        for (s, want) in [("0", 0.0), ("-1", -1.0), ("2.5e3", 2500.0), ("1e-3", 1e-3)] {
            assert_eq!(Json::parse(s).unwrap().as_f64().unwrap(), want, "{s}");
        }
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""aébA 😀 \\n""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "aébA 😀 \\n");
        // non-ascii passthrough
        let j = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\q\"", "[1] tail", ""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap().to_string_compact(), "[]");
    }

    #[test]
    fn canonical_form_is_order_and_spelling_insensitive() {
        // same object, different key order and number/whitespace spellings
        let a = Json::parse(r#"{"b": 2.0, "a": [1, {"y": true, "x": null}]}"#).unwrap();
        let b = Json::parse(r#"{ "a":[1.0,{ "x":null,"y":true }],"b":2 }"#).unwrap();
        assert_eq!(a.to_canonical_string(), b.to_canonical_string());
        // and a semantic difference shows in the bytes
        let c = Json::parse(r#"{"b": 2.5, "a": [1, {"y": true, "x": null}]}"#).unwrap();
        assert_ne!(a.to_canonical_string(), c.to_canonical_string());
    }

    #[test]
    fn int_vs_float_formatting() {
        assert_eq!(Json::num(32.0).to_string_compact(), "32");
        assert_eq!(Json::num(0.05).to_string_compact(), "0.05");
        assert_eq!(Json::num(f64::NAN).to_string_compact(), "null");
    }
}

//! Typed configuration: Table III experimental settings, framework
//! selection, and per-experiment overrides (JSON-loadable, CLI-overridable).

use anyhow::{bail, Context, Result};

use crate::jsonio::Json;

/// Which FL framework drives a run (§V baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameworkKind {
    /// The paper's contribution: mutual learning + inversion + P1/P2.
    SplitMe,
    /// FedAvg [6]: fixed K=10, E=10, no splitting, no system optimization.
    FedAvg,
    /// Vanilla SplitFed [12]: fixed K=20, E=14, per-batch smashed ping-pong.
    Sfl,
    /// O-RANFed [8]: deadline-aware selection + bandwidth allocation, no split.
    OranFed,
}

impl FrameworkKind {
    pub fn name(&self) -> &'static str {
        match self {
            Self::SplitMe => "splitme",
            Self::FedAvg => "fedavg",
            Self::Sfl => "sfl",
            Self::OranFed => "oranfed",
        }
    }

    pub fn all() -> [FrameworkKind; 4] {
        [Self::SplitMe, Self::FedAvg, Self::Sfl, Self::OranFed]
    }
}

impl std::str::FromStr for FrameworkKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "splitme" | "split-me" | "split_me" => Ok(Self::SplitMe),
            "fedavg" | "fed-avg" => Ok(Self::FedAvg),
            "sfl" | "splitfed" => Ok(Self::Sfl),
            "oranfed" | "o-ranfed" | "oran-fed" => Ok(Self::OranFed),
            other => bail!("unknown framework {other:?} (splitme|fedavg|sfl|oranfed)"),
        }
    }
}

/// Table III of the paper + simulator knobs. All times in seconds, bandwidth
/// in bits/s, sizes in bytes.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// artifact preset: "commag" (§V main) or "vision" (Fig 5)
    pub preset: String,
    /// M — maximum number of local trainers (near-RT-RICs)
    pub num_clients: usize,
    /// B — total uplink bandwidth budget (bits/s); Table III: 1 Gbps
    pub bandwidth_bps: f64,
    /// Q_C,m ~ U(lo, hi) per-batch client processing time (s)
    pub q_c_range: (f64, f64),
    /// Q_S,m ~ U(lo, hi) per-batch server processing time (s)
    pub q_s_range: (f64, f64),
    /// p_c — per-unit communication cost
    pub p_c: f64,
    /// p_tr — per-unit-time computation cost
    pub p_tr: f64,
    /// b_min — minimum bandwidth fraction for a selected client (<= 1/M)
    pub b_min: f64,
    /// omega — client share of the full model parameters (Table III: 1/5)
    pub omega: f64,
    /// rho — Pareto trade-off between resource cost and learning time
    pub rho: f64,
    /// rho_E — weight of the per-round energy term in the P2′ objective.
    /// 0 (the default) disables energy pricing structurally and keeps the
    /// solver bitwise identical to the pre-P2′ path — see
    /// `oran::EnergyModel` and PERF.md §allocation-P2′
    pub rho_e: f64,
    /// base radio transmit power (W) per uploading client (P2′ energy term)
    pub p_tx: f64,
    /// base compute power (W) per training client (P2′ energy term)
    pub p_cmp: f64,
    /// t_round ~ U(lo, hi) slice-specific control-loop deadline (s)
    pub t_round_range: (f64, f64),
    /// alpha — heuristic factor of Algorithm 1
    pub alpha: f64,
    /// E_initial / N (=E_max) — local update bounds (§IV-D)
    pub e_initial: usize,
    pub e_max: usize,
    /// epsilon in K_eps = O((E+1)^2 / (E^2 eps^2)) (Corollary 4 / 22f)
    pub epsilon: f64,
    /// samples held by each near-RT-RIC (one slice class each — non-IID)
    pub samples_per_client: usize,
    /// balanced test-set size
    pub test_samples: usize,
    /// class-separation knob of the synthetic COMMAG generator (DESIGN.md §3)
    pub data_difficulty: f64,
    /// root seed for every RNG stream
    pub seed: u64,
    /// environment source of the dynamic scenario engine: a named preset
    /// (`static|fading|churn|rush_hour|stragglers|slice_fading`) or a
    /// trace replay (`trace:<path.csv|.json>` — the file schema is in
    /// `scenario::trace`). `static` is today's stationary substrate and
    /// the default — see `scenario::ScenarioKind`
    pub scenario: String,
    /// fault-injection preset (`none|dropout|flaky_uplink|crash_loop`).
    /// `none` (the default) draws no randomness and keeps the historical
    /// bitwise-identical code path — see `faults::FaultKind` and
    /// PERF.md §fault-model
    pub faults: String,
    /// minimum surviving clients for a round's aggregation to proceed;
    /// below it the round is recorded as a quorum miss (skipped), never a
    /// panic. Must be >= 1 (an empty aggregation is undefined)
    pub fault_quorum: usize,
    /// base retry backoff (s): upload retry k waits retry_backoff_s·2^(k-1),
    /// budgeted against the client's remaining deadline slack
    pub retry_backoff_s: f64,
    /// snapshot RunState to disk every K rounds (0 = disabled); the path is
    /// a CLI concern (`repro run --checkpoint`)
    pub checkpoint_every: usize,
    /// evaluate every k rounds (1 = every round, figures need 1)
    pub eval_every: usize,
    /// ridge regularizer gamma of Eq 8 (Step-4 inversion)
    pub ridge_gamma: f64,
    /// how many rApps pool Gram statistics in the inversion (must supply
    /// more samples than the widest server layer's d_in+1)
    pub inversion_clients: usize,
    /// stop a run early once test accuracy reaches this (paper: 83%)
    pub target_accuracy: f32,
    pub stop_at_target: bool,
    /// learning-rate overrides (None -> manifest defaults, eta_c > eta_s)
    pub eta_c: Option<f32>,
    pub eta_s: Option<f32>,
    /// cap (bytes) on the per-context chunk-stack precompute; 0 = unlimited.
    /// When the projected stack size exceeds the cap, the precompute is
    /// skipped and chunked dispatch falls back to the (slower, numerically
    /// identical) single-step path — PERF.md §memory. The whole-shard smash
    /// stacks share this budget.
    pub chunk_cache_cap_bytes: usize,
    /// worker threads for the per-selected-client phase inside every round
    /// (0 = auto: `REPRO_CLIENT_JOBS` env, else 1 — sequential). Purely an
    /// execution knob: any value is bitwise identical (the differential
    /// suite is the gate), total thread footprint multiplies with `--jobs`
    /// — PERF.md §client-parallelism.
    pub client_jobs: usize,
    /// cap on how many candidates the deadline-aware selectors admit per
    /// round (0 = off, the historical unbounded behavior). With a cap the
    /// selection runs as a streaming top-k over candidate shards instead of
    /// a full O(M log M) sort — the federation-scale path (PERF.md
    /// §federation-scale). Applies to SplitMe and O-RANFed; the fixed-K
    /// baselines already bound their own K.
    pub select_cap: usize,
    /// how many trailing `RoundRecord`s `RunState` retains in memory
    /// (0 = unbounded, the historical behavior). `RunSummary` totals are
    /// accumulated incrementally and stay identical under any window;
    /// incompatible with `checkpoint_every` (checkpoints embed the full
    /// record history for bitwise resume).
    pub record_window: usize,
    /// distinct synthetic data shards: client m trains shard `m % S`
    /// (0 = auto: S = M for M <= 256, else 240 — divisible by both the
    /// 3-slice commag and 10-class vision cycles, so sharded populations
    /// keep the exact class mix). Bounds dataset memory at federation
    /// scale; small-M runs are bitwise unchanged.
    pub data_shards: usize,
    /// force the dense reference path: full per-client env/fault vectors
    /// and cold Markov replay from round 0 (the pre-federation-scale
    /// behavior). Only useful to differential-test the lazy path against;
    /// never faster.
    pub reference_path: bool,
    /// fixed-K baselines (FedAvg K=10/E=10, SFL K=20/E=14 per §V)
    pub fedavg_k: usize,
    pub fedavg_e: usize,
    pub sfl_k: usize,
    pub sfl_e: usize,
    pub oranfed_e: usize,
}

impl SimConfig {
    /// Table III defaults on the COMMAG-like workload.
    pub fn commag() -> Self {
        Self {
            preset: "commag".into(),
            num_clients: 50,
            bandwidth_bps: 1e9,
            q_c_range: (0.34e-3, 0.46e-3),
            q_s_range: (1.2e-3, 1.6e-3),
            p_c: 1.0,
            p_tr: 1.0,
            b_min: 1.0 / 50.0,
            omega: 0.2,
            rho: 0.8,
            rho_e: 0.0,
            p_tx: 2.0,
            p_cmp: 5.0,
            t_round_range: (50e-3, 100e-3),
            alpha: 0.7,
            e_initial: 20,
            e_max: 20,
            epsilon: 0.1,
            samples_per_client: 512,
            test_samples: 1536,
            data_difficulty: 1.0,
            seed: 20250710,
            scenario: "static".into(),
            faults: "none".into(),
            fault_quorum: 1,
            retry_backoff_s: 0.05,
            checkpoint_every: 0,
            eval_every: 1,
            ridge_gamma: 1.0,
            inversion_clients: 12,
            target_accuracy: 0.775,
            stop_at_target: false,
            eta_c: Some(0.03),
            eta_s: Some(0.02),
            chunk_cache_cap_bytes: 0,
            client_jobs: 0,
            select_cap: 0,
            record_window: 0,
            data_shards: 0,
            reference_path: false,
            fedavg_k: 10,
            fedavg_e: 10,
            sfl_k: 20,
            sfl_e: 14,
            oranfed_e: 10,
        }
    }

    /// Fig-5 analogue: the vision preset with a smaller federation (the
    /// conv model is ~20x heavier per step on the CPU testbed).
    pub fn vision() -> Self {
        Self {
            preset: "vision".into(),
            num_clients: 10,
            b_min: 1.0 / 10.0,
            samples_per_client: 128,
            test_samples: 512,
            fedavg_k: 4,
            sfl_k: 4,
            sfl_e: 8,
            // widest vision layer has d_in+1 = 1025 unknowns: pool all 10
            // clients (10*128 = 1280 samples) in the inversion
            inversion_clients: 10,
            target_accuracy: 0.80,
            ..Self::commag()
        }
    }

    pub fn preset_config(name: &str) -> Result<Self> {
        match name {
            "commag" => Ok(Self::commag()),
            "vision" => Ok(Self::vision()),
            other => bail!("unknown config preset {other:?} (commag|vision)"),
        }
    }

    /// Load a user-supplied config file: unreadable paths carry
    /// [`crate::errors::ReproError::Io`], malformed JSON
    /// [`crate::errors::ReproError::InvalidInput`] (CLI exit codes 3/2).
    pub fn from_json_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::Error::new(crate::errors::ReproError::io(path, e)))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::Error::new(crate::errors::ReproError::invalid(format!("{e:#}"))))
            .with_context(|| format!("parsing SimConfig json {path}"))?;
        let cfg = Self::from_json(&j)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to JSON (all fields; pairs as 2-arrays).
    pub fn to_json(&self) -> Json {
        let pair = |p: (f64, f64)| Json::arr(vec![Json::num(p.0), Json::num(p.1)]);
        let opt = |o: Option<f32>| o.map(|v| Json::num(v as f64)).unwrap_or(Json::Null);
        Json::obj(vec![
            ("preset", Json::str(self.preset.clone())),
            ("num_clients", Json::num(self.num_clients as f64)),
            ("bandwidth_bps", Json::num(self.bandwidth_bps)),
            ("q_c_range", pair(self.q_c_range)),
            ("q_s_range", pair(self.q_s_range)),
            ("p_c", Json::num(self.p_c)),
            ("p_tr", Json::num(self.p_tr)),
            ("b_min", Json::num(self.b_min)),
            ("omega", Json::num(self.omega)),
            ("rho", Json::num(self.rho)),
            ("rho_e", Json::num(self.rho_e)),
            ("p_tx", Json::num(self.p_tx)),
            ("p_cmp", Json::num(self.p_cmp)),
            ("t_round_range", pair(self.t_round_range)),
            ("alpha", Json::num(self.alpha)),
            ("e_initial", Json::num(self.e_initial as f64)),
            ("e_max", Json::num(self.e_max as f64)),
            ("epsilon", Json::num(self.epsilon)),
            ("samples_per_client", Json::num(self.samples_per_client as f64)),
            ("test_samples", Json::num(self.test_samples as f64)),
            ("data_difficulty", Json::num(self.data_difficulty)),
            ("seed", Json::num(self.seed as f64)),
            ("scenario", Json::str(self.scenario.clone())),
            ("faults", Json::str(self.faults.clone())),
            ("fault_quorum", Json::num(self.fault_quorum as f64)),
            ("retry_backoff_s", Json::num(self.retry_backoff_s)),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("ridge_gamma", Json::num(self.ridge_gamma)),
            ("inversion_clients", Json::num(self.inversion_clients as f64)),
            ("target_accuracy", Json::num(self.target_accuracy as f64)),
            ("stop_at_target", Json::Bool(self.stop_at_target)),
            ("eta_c", opt(self.eta_c)),
            ("eta_s", opt(self.eta_s)),
            ("chunk_cache_cap_bytes", Json::num(self.chunk_cache_cap_bytes as f64)),
            ("client_jobs", Json::num(self.client_jobs as f64)),
            ("select_cap", Json::num(self.select_cap as f64)),
            ("record_window", Json::num(self.record_window as f64)),
            ("data_shards", Json::num(self.data_shards as f64)),
            ("reference_path", Json::Bool(self.reference_path)),
            ("fedavg_k", Json::num(self.fedavg_k as f64)),
            ("fedavg_e", Json::num(self.fedavg_e as f64)),
            ("sfl_k", Json::num(self.sfl_k as f64)),
            ("sfl_e", Json::num(self.sfl_e as f64)),
            ("oranfed_e", Json::num(self.oranfed_e as f64)),
        ])
    }

    /// Parse from JSON. Missing keys fall back to the preset named by
    /// `"preset"` (so partial override files stay valid).
    pub fn from_json(j: &Json) -> Result<Self> {
        let preset = j
            .opt("preset")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "commag".to_string());
        let mut cfg = Self::preset_config(&preset)?;
        let pair = |v: &Json| -> Result<(f64, f64)> {
            let a = v.as_arr()?;
            if a.len() != 2 {
                bail!("range must be a 2-array");
            }
            Ok((a[0].as_f64()?, a[1].as_f64()?))
        };
        if let Some(v) = j.opt("num_clients") { cfg.num_clients = v.as_usize()?; }
        if let Some(v) = j.opt("bandwidth_bps") { cfg.bandwidth_bps = v.as_f64()?; }
        if let Some(v) = j.opt("q_c_range") { cfg.q_c_range = pair(v)?; }
        if let Some(v) = j.opt("q_s_range") { cfg.q_s_range = pair(v)?; }
        if let Some(v) = j.opt("p_c") { cfg.p_c = v.as_f64()?; }
        if let Some(v) = j.opt("p_tr") { cfg.p_tr = v.as_f64()?; }
        if let Some(v) = j.opt("b_min") { cfg.b_min = v.as_f64()?; }
        if let Some(v) = j.opt("omega") { cfg.omega = v.as_f64()?; }
        if let Some(v) = j.opt("rho") { cfg.rho = v.as_f64()?; }
        if let Some(v) = j.opt("rho_e") { cfg.rho_e = v.as_f64()?; }
        if let Some(v) = j.opt("p_tx") { cfg.p_tx = v.as_f64()?; }
        if let Some(v) = j.opt("p_cmp") { cfg.p_cmp = v.as_f64()?; }
        if let Some(v) = j.opt("t_round_range") { cfg.t_round_range = pair(v)?; }
        if let Some(v) = j.opt("alpha") { cfg.alpha = v.as_f64()?; }
        if let Some(v) = j.opt("e_initial") { cfg.e_initial = v.as_usize()?; }
        if let Some(v) = j.opt("e_max") { cfg.e_max = v.as_usize()?; }
        if let Some(v) = j.opt("epsilon") { cfg.epsilon = v.as_f64()?; }
        if let Some(v) = j.opt("samples_per_client") { cfg.samples_per_client = v.as_usize()?; }
        if let Some(v) = j.opt("test_samples") { cfg.test_samples = v.as_usize()?; }
        if let Some(v) = j.opt("data_difficulty") { cfg.data_difficulty = v.as_f64()?; }
        if let Some(v) = j.opt("seed") { cfg.seed = v.as_f64()? as u64; }
        if let Some(v) = j.opt("scenario") { cfg.scenario = v.as_str()?.to_string(); }
        if let Some(v) = j.opt("faults") { cfg.faults = v.as_str()?.to_string(); }
        if let Some(v) = j.opt("fault_quorum") { cfg.fault_quorum = v.as_usize()?; }
        if let Some(v) = j.opt("retry_backoff_s") { cfg.retry_backoff_s = v.as_f64()?; }
        if let Some(v) = j.opt("checkpoint_every") { cfg.checkpoint_every = v.as_usize()?; }
        if let Some(v) = j.opt("eval_every") { cfg.eval_every = v.as_usize()?; }
        if let Some(v) = j.opt("ridge_gamma") { cfg.ridge_gamma = v.as_f64()?; }
        if let Some(v) = j.opt("inversion_clients") { cfg.inversion_clients = v.as_usize()?; }
        if let Some(v) = j.opt("target_accuracy") { cfg.target_accuracy = v.as_f64()? as f32; }
        if let Some(v) = j.opt("stop_at_target") { cfg.stop_at_target = v.as_bool()?; }
        if let Some(v) = j.opt("eta_c") {
            cfg.eta_c = match v {
                Json::Null => None,
                other => Some(other.as_f64()? as f32),
            };
        }
        if let Some(v) = j.opt("eta_s") {
            cfg.eta_s = match v {
                Json::Null => None,
                other => Some(other.as_f64()? as f32),
            };
        }
        if let Some(v) = j.opt("chunk_cache_cap_bytes") { cfg.chunk_cache_cap_bytes = v.as_usize()?; }
        if let Some(v) = j.opt("client_jobs") { cfg.client_jobs = v.as_usize()?; }
        if let Some(v) = j.opt("select_cap") { cfg.select_cap = v.as_usize()?; }
        if let Some(v) = j.opt("record_window") { cfg.record_window = v.as_usize()?; }
        if let Some(v) = j.opt("data_shards") { cfg.data_shards = v.as_usize()?; }
        if let Some(v) = j.opt("reference_path") { cfg.reference_path = v.as_bool()?; }
        if let Some(v) = j.opt("fedavg_k") { cfg.fedavg_k = v.as_usize()?; }
        if let Some(v) = j.opt("fedavg_e") { cfg.fedavg_e = v.as_usize()?; }
        if let Some(v) = j.opt("sfl_k") { cfg.sfl_k = v.as_usize()?; }
        if let Some(v) = j.opt("sfl_e") { cfg.sfl_e = v.as_usize()?; }
        if let Some(v) = j.opt("oranfed_e") { cfg.oranfed_e = v.as_usize()?; }
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.num_clients == 0 {
            bail!("num_clients must be > 0");
        }
        if !(self.b_min > 0.0 && self.b_min <= 1.0 / self.num_clients as f64 + 1e-12) {
            bail!("b_min must be in (0, 1/M]; got {} for M={}", self.b_min, self.num_clients);
        }
        if !(0.0..=1.0).contains(&self.rho) {
            bail!("rho must be in [0,1]");
        }
        if !(0.0..=1.0).contains(&self.alpha) {
            bail!("alpha must be in [0,1]");
        }
        if !(self.rho_e.is_finite() && self.rho_e >= 0.0) {
            bail!("rho_e must be finite and >= 0; got {}", self.rho_e);
        }
        if !(self.p_tx.is_finite() && self.p_tx >= 0.0)
            || !(self.p_cmp.is_finite() && self.p_cmp >= 0.0)
        {
            bail!("energy powers p_tx/p_cmp must be finite and >= 0");
        }
        if self.e_initial == 0 || self.e_max == 0 || self.e_initial > self.e_max {
            bail!("need 1 <= e_initial <= e_max");
        }
        if self.q_c_range.0 > self.q_c_range.1 || self.q_s_range.0 > self.q_s_range.1 {
            bail!("Q ranges must be lo <= hi");
        }
        if self.t_round_range.0 > self.t_round_range.1 {
            bail!("t_round range must be lo <= hi");
        }
        if self.bandwidth_bps <= 0.0 {
            bail!("bandwidth must be positive");
        }
        // fail early on a typo'd preset name (the scenario engine would
        // reject it at context build anyway, but this keeps the error at
        // config-load time with the other validation messages)
        self.scenario
            .parse::<crate::scenario::ScenarioKind>()
            .map(|_| ())
            .map_err(|e| anyhow::anyhow!("invalid scenario: {e}"))?;
        // same early-failure treatment for the fault preset spelling
        self.faults
            .parse::<crate::faults::FaultKind>()
            .map(|_| ())
            .map_err(|e| anyhow::anyhow!("invalid faults: {e}"))?;
        if self.fault_quorum == 0 {
            bail!("fault_quorum must be >= 1 (an empty aggregation is undefined)");
        }
        if !(self.retry_backoff_s.is_finite() && self.retry_backoff_s >= 0.0) {
            bail!("retry_backoff_s must be finite and >= 0; got {}", self.retry_backoff_s);
        }
        if self.checkpoint_every > 0 && self.record_window > 0 {
            bail!(
                "checkpoint_every and record_window are mutually exclusive: checkpoints \
                 embed the full record history for bitwise resume, a window discards it"
            );
        }
        Ok(())
    }

    /// The resolved synthetic-data shard count S: client m trains shard
    /// `m % S`. `data_shards = 0` (auto) keeps S = M for M <= 256 — every
    /// client its own shard, bitwise identical to the unsharded generator —
    /// and caps S at 240 beyond that (240 = lcm(3, 10)·8, so the commag
    /// 3-slice and vision 10-class cycles both divide it and `m % S`
    /// preserves each client's class).
    pub fn shard_count(&self) -> usize {
        let s = match self.data_shards {
            0 => {
                if self.num_clients <= 256 {
                    self.num_clients
                } else {
                    240
                }
            }
            s => s,
        };
        s.min(self.num_clients).max(1)
    }

    /// K_eps(E) of constraint (22f): O((E+1)^2 / (E^2 eps^2)).
    pub fn k_eps(&self, e: usize) -> f64 {
        let e = e as f64;
        (e + 1.0) * (e + 1.0) / (e * e * self.epsilon * self.epsilon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_iii_defaults() {
        let c = SimConfig::commag();
        assert_eq!(c.num_clients, 50);
        assert_eq!(c.bandwidth_bps, 1e9);
        assert_eq!(c.b_min, 0.02);
        assert_eq!(c.omega, 0.2);
        assert_eq!(c.rho, 0.8);
        assert_eq!(c.alpha, 0.7);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn k_eps_decreases_with_e() {
        let c = SimConfig::commag();
        // Corollary 4: more local updates -> fewer communication rounds
        assert!(c.k_eps(1) > c.k_eps(5));
        assert!(c.k_eps(5) > c.k_eps(20));
        // and tends to 1/eps^2
        assert!((c.k_eps(10_000) - 1.0 / (0.1f64 * 0.1)).abs() < 1.0);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = SimConfig::commag();
        c.b_min = 0.5;
        assert!(c.validate().is_err());
        let mut c = SimConfig::commag();
        c.rho = 1.5;
        assert!(c.validate().is_err());
        let mut c = SimConfig::commag();
        c.e_initial = 30;
        assert!(c.validate().is_err());
        let mut c = SimConfig::commag();
        c.scenario = "typo_hour".into();
        assert!(c.validate().is_err());
        let mut c = SimConfig::commag();
        c.faults = "typo_loop".into();
        assert!(c.validate().is_err());
        let mut c = SimConfig::commag();
        c.fault_quorum = 0;
        assert!(c.validate().is_err());
        let mut c = SimConfig::commag();
        c.retry_backoff_s = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = SimConfig::commag();
        c.retry_backoff_s = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn fault_fields_default_off_and_round_trip() {
        let c = SimConfig::commag();
        assert_eq!(c.faults, "none");
        assert_eq!(c.fault_quorum, 1);
        assert_eq!(c.checkpoint_every, 0);
        let mut c = SimConfig::commag();
        c.faults = "flaky_uplink".into();
        c.fault_quorum = 3;
        c.retry_backoff_s = 0.02;
        c.checkpoint_every = 10;
        assert!(c.validate().is_ok());
        let back =
            SimConfig::from_json(&Json::parse(&c.to_json().to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.faults, "flaky_uplink");
        assert_eq!(back.fault_quorum, 3);
        assert_eq!(back.retry_backoff_s, 0.02);
        assert_eq!(back.checkpoint_every, 10);
        // partial override files keep the quiet defaults
        let j = Json::parse(r#"{"preset": "commag", "num_clients": 12, "b_min": 0.05}"#).unwrap();
        let c = SimConfig::from_json(&j).unwrap();
        assert_eq!(c.faults, "none");
        assert_eq!(c.fault_quorum, 1);
    }

    #[test]
    fn trace_scenario_specs_validate_syntactically() {
        // validate() checks the SPELLING only — file existence is a
        // context-build (Scenario::new) concern, so configs stay portable
        let mut c = SimConfig::commag();
        c.scenario = "trace:examples/traces/oran_diurnal_load.csv".into();
        assert!(c.validate().is_ok());
        c.scenario = "slice_fading".into();
        assert!(c.validate().is_ok());
        c.scenario = "trace:".into();
        assert!(c.validate().is_err(), "empty trace path must fail validation");
        // and the spec round-trips through config JSON like any string
        c.scenario = "trace:/tmp/t.json".into();
        let back =
            SimConfig::from_json(&Json::parse(&c.to_json().to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.scenario, "trace:/tmp/t.json");
    }

    #[test]
    fn scenario_defaults_to_static_and_round_trips() {
        let c = SimConfig::commag();
        assert_eq!(c.scenario, "static");
        assert!(c.validate().is_ok());
        let mut c = SimConfig::vision();
        c.scenario = "churn".into();
        assert!(c.validate().is_ok());
        let back = SimConfig::from_json(&Json::parse(&c.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back.scenario, "churn");
        // partial override files keep the preset default
        let j = Json::parse(r#"{"preset": "commag", "num_clients": 12, "b_min": 0.05}"#).unwrap();
        assert_eq!(SimConfig::from_json(&j).unwrap().scenario, "static");
    }

    #[test]
    fn json_roundtrip() {
        let mut c = SimConfig::vision();
        c.num_clients = 7;
        c.b_min = 1.0 / 7.0;
        c.eta_c = Some(0.01);
        c.chunk_cache_cap_bytes = 64 << 20;
        c.client_jobs = 3;
        let s = c.to_json().to_string_pretty();
        let back = SimConfig::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(back.preset, "vision");
        assert_eq!(back.num_clients, 7);
        assert_eq!(back.eta_c, Some(0.01));
        assert_eq!(back.chunk_cache_cap_bytes, 64 << 20);
        assert_eq!(back.client_jobs, 3);
        assert_eq!(back.sfl_e, c.sfl_e);
    }

    #[test]
    fn json_partial_override_falls_back_to_preset() {
        let j = Json::parse(r#"{"preset": "commag", "num_clients": 12, "b_min": 0.05}"#).unwrap();
        let c = SimConfig::from_json(&j).unwrap();
        assert_eq!(c.num_clients, 12);
        assert_eq!(c.b_min, 0.05);
        assert_eq!(c.fedavg_k, 10); // untouched default
    }

    #[test]
    fn scale_knobs_default_off_and_round_trip() {
        let c = SimConfig::commag();
        assert_eq!((c.select_cap, c.record_window, c.data_shards), (0, 0, 0));
        assert!(!c.reference_path);
        let mut c = SimConfig::commag();
        c.select_cap = 16;
        c.record_window = 4;
        c.data_shards = 30;
        c.reference_path = true;
        assert!(c.validate().is_ok());
        let back =
            SimConfig::from_json(&Json::parse(&c.to_json().to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.select_cap, 16);
        assert_eq!(back.record_window, 4);
        assert_eq!(back.data_shards, 30);
        assert!(back.reference_path);
        // a record window discards the history a checkpoint must embed
        let mut c = SimConfig::commag();
        c.checkpoint_every = 5;
        c.record_window = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn energy_knobs_default_off_and_round_trip() {
        let c = SimConfig::commag();
        assert_eq!(c.rho_e, 0.0, "energy term must default off (bitwise gate)");
        assert_eq!((c.p_tx, c.p_cmp), (2.0, 5.0));
        let mut c = SimConfig::commag();
        c.rho_e = 0.3;
        c.p_tx = 1.5;
        c.p_cmp = 7.0;
        assert!(c.validate().is_ok());
        let back =
            SimConfig::from_json(&Json::parse(&c.to_json().to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.rho_e, 0.3);
        assert_eq!(back.p_tx, 1.5);
        assert_eq!(back.p_cmp, 7.0);
        // partial override files keep the quiet default
        let j = Json::parse(r#"{"preset": "commag", "num_clients": 12, "b_min": 0.05}"#).unwrap();
        assert_eq!(SimConfig::from_json(&j).unwrap().rho_e, 0.0);
        let mut c = SimConfig::commag();
        c.rho_e = -0.1;
        assert!(c.validate().is_err());
        let mut c = SimConfig::commag();
        c.p_tx = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn shard_count_auto_rule() {
        let mut c = SimConfig::commag();
        assert_eq!(c.shard_count(), 50); // M <= 256: every client its own shard
        c.num_clients = 256;
        assert_eq!(c.shard_count(), 256);
        c.num_clients = 100_000;
        assert_eq!(c.shard_count(), 240); // divisible by 3 and 10: class mix kept
        c.data_shards = 30;
        assert_eq!(c.shard_count(), 30);
        c.data_shards = 1_000_000; // explicit S never exceeds M
        assert_eq!(c.shard_count(), 100_000);
    }

    #[test]
    fn framework_kind_parses() {
        use std::str::FromStr;
        assert_eq!(FrameworkKind::from_str("splitme").unwrap(), FrameworkKind::SplitMe);
        assert_eq!(FrameworkKind::from_str("SFL").unwrap(), FrameworkKind::Sfl);
        assert!(FrameworkKind::from_str("nope").is_err());
    }
}

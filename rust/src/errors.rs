//! Typed error taxonomy (`ReproError`) threaded to CLI exit codes.
//!
//! The crate keeps `anyhow` for ergonomic context chains, but failures that
//! callers (CI, sweep drivers, the xApp harness) need to *classify* — bad
//! user input, I/O on user-supplied paths, a panic captured inside an
//! executor job — carry a `ReproError` somewhere in the chain.
//! `main()` walks the chain with [`ReproError::exit_code_of`] and maps the
//! first typed error to a distinct nonzero exit code:
//!
//! | code | meaning                                              |
//! |------|------------------------------------------------------|
//! | 1    | unclassified error (anyhow chain without a ReproError) |
//! | 2    | invalid user input: CLI flag, config/trace/checkpoint content |
//! | 3    | I/O failure on a user-supplied path                  |
//! | 4    | a job panicked inside the executor (panic-isolated)  |

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReproError {
    /// Malformed user input: an unparseable CLI flag, an invalid config
    /// field, a trace/checkpoint file whose *content* is bad.
    InvalidInput(String),
    /// Filesystem I/O failed on a user-supplied path.
    Io { path: String, message: String },
    /// A panic captured inside an executor job (`executor::try_run_indexed`):
    /// the job failed, the rest of the batch completed.
    JobPanic { index: usize, message: String },
}

impl ReproError {
    pub fn exit_code(&self) -> i32 {
        match self {
            Self::InvalidInput(_) => 2,
            Self::Io { .. } => 3,
            Self::JobPanic { .. } => 4,
        }
    }

    /// The first `ReproError` in an anyhow chain, if any — the shared
    /// classifier behind [`ReproError::exit_code_of`] and the experiment
    /// service's typed protocol responses (`serve`), which must agree on
    /// what counts as invalid input.
    pub fn of_chain(e: &anyhow::Error) -> Option<&ReproError> {
        e.chain().find_map(|c| c.downcast_ref::<ReproError>())
    }

    /// The exit code for an anyhow chain: the first `ReproError` found wins;
    /// an untyped chain maps to the generic failure code 1.
    pub fn exit_code_of(e: &anyhow::Error) -> i32 {
        Self::of_chain(e).map(|r| r.exit_code()).unwrap_or(1)
    }

    /// Wrap a `std::io::Result` context into the typed taxonomy.
    pub fn io(path: impl fmt::Display, err: impl fmt::Display) -> Self {
        Self::Io { path: path.to_string(), message: err.to_string() }
    }

    pub fn invalid(msg: impl Into<String>) -> Self {
        Self::InvalidInput(msg.into())
    }
}

impl fmt::Display for ReproError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::InvalidInput(msg) => write!(f, "invalid input: {msg}"),
            Self::Io { path, message } => write!(f, "io error on {path}: {message}"),
            Self::JobPanic { index, message } => {
                write!(f, "job {index} panicked: {message}")
            }
        }
    }
}

impl std::error::Error for ReproError {}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Context;

    #[test]
    fn exit_codes_are_distinct_and_stable() {
        assert_eq!(ReproError::invalid("x").exit_code(), 2);
        assert_eq!(ReproError::io("p", "e").exit_code(), 3);
        assert_eq!(ReproError::JobPanic { index: 0, message: "boom".into() }.exit_code(), 4);
    }

    #[test]
    fn exit_code_of_walks_context_chains() {
        let e = anyhow::Error::new(ReproError::invalid("bad flag")).context("parsing argv");
        assert_eq!(ReproError::exit_code_of(&e), 2);
        let e = anyhow::anyhow!("plain untyped failure");
        assert_eq!(ReproError::exit_code_of(&e), 1);
        let e = anyhow::Error::new(ReproError::JobPanic { index: 3, message: "x".into() })
            .context("running comparison")
            .context("experiment all");
        assert_eq!(ReproError::exit_code_of(&e), 4);
    }

    #[test]
    fn display_messages_are_actionable() {
        let msg = ReproError::io("/tmp/x.json", "No such file or directory").to_string();
        assert!(msg.contains("/tmp/x.json"));
        let msg = ReproError::JobPanic { index: 7, message: "index out of bounds".into() }.to_string();
        assert!(msg.contains("job 7"));
    }
}

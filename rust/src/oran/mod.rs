//! O-RAN system substrate: the topology, channel, cost, and latency models
//! of §IV (Eq 16–20).
//!
//! One regional cloud (non-RT-RIC, hosting the rApps) plus `M` near-RT-RICs
//! (each an xApp-running edge server). Per-batch processing times `Q_{C,m}`,
//! `Q_{S,m}` and slice-specific control-loop deadlines `t_round` are drawn
//! per client from the Table III distributions (the paper's own emulation
//! parameters — this IS the paper's hardware model, see DESIGN.md §3).
//! The m-plane fiber uplink has total budget `B`; a round's allocation is a
//! fraction vector over the selected clients.

use crate::config::SimConfig;
use crate::sim::{uniform, RngPool};

/// Static profile of one near-RT-RIC / xApp / rApp trio.
#[derive(Debug, Clone)]
pub struct RicProfile {
    pub id: usize,
    /// slice class served (0=eMBB, 1=mMTC, 2=URLLC) — sets the deadline class
    pub slice_class: usize,
    /// Q_C,m: per-batch client-side processing time (s)
    pub q_c: f64,
    /// Q_S,m: per-batch server-side (rApp GPU) processing time (s)
    pub q_s: f64,
    /// t_round: slice-specific O-RAN control-loop deadline (s)
    pub t_round: f64,
    /// local sample count (sets the smashed-data upload size S_m)
    pub n_samples: usize,
}

/// The whole O-RAN federation.
#[derive(Debug, Clone)]
pub struct Topology {
    pub rics: Vec<RicProfile>,
    /// total uplink bandwidth B (bits/s)
    pub bandwidth_bps: f64,
}

impl Topology {
    /// Build from config; all draws come from dedicated RNG substreams so
    /// the topology is identical across frameworks (paired comparison).
    pub fn build(cfg: &SimConfig) -> Self {
        let pool = RngPool::new(cfg.seed);
        let rics = (0..cfg.num_clients)
            .map(|m| {
                let mut rng = pool.stream("ric_profile", m as u64);
                RicProfile {
                    id: m,
                    slice_class: m % 3,
                    q_c: uniform(&mut rng, cfg.q_c_range.0, cfg.q_c_range.1),
                    q_s: uniform(&mut rng, cfg.q_s_range.0, cfg.q_s_range.1),
                    t_round: uniform(&mut rng, cfg.t_round_range.0, cfg.t_round_range.1),
                    n_samples: cfg.samples_per_client,
                }
            })
            .collect();
        Self { rics, bandwidth_bps: cfg.bandwidth_bps }
    }

    pub fn len(&self) -> usize {
        self.rics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rics.is_empty()
    }

    /// The candidate with the largest deadline slack `t_round -
    /// compute_time(r)` — the empty-selection fallback shared by the
    /// deadline-aware frameworks (SplitMe, O-RANFed): when no RIC meets its
    /// deadline, the least-bad one still trains so the round progresses and
    /// the t_estimate feedback can relax.
    pub fn most_slack<F: Fn(&RicProfile) -> f64>(&self, compute_time: F) -> Option<&RicProfile> {
        self.rics.iter().max_by(|a, b| {
            let slack = |r: &RicProfile| r.t_round - compute_time(r);
            slack(a).total_cmp(&slack(b))
        })
    }

    /// Profile of client `id`. On the full topology ids are positions; on a
    /// scenario-filtered effective topology (`RoundEnv::apply`) positions
    /// shift, so look up by the preserved id. Linear scan — M is tens.
    pub fn by_id(&self, id: usize) -> Option<&RicProfile> {
        // fast path: on an unfiltered topology rics[id].id == id
        if let Some(r) = self.rics.get(id) {
            if r.id == id {
                return Some(r);
            }
        }
        self.rics.iter().find(|r| r.id == id)
    }
}

/// Per-round wire sizes (bytes) of one framework's uplink traffic.
#[derive(Debug, Clone, Copy, Default)]
pub struct UploadSizes {
    /// model-parameter bytes uploaded by client m (omega*d, or d unsplit)
    pub model_bytes: f64,
    /// intermediate-feature bytes uploaded by client m per round
    pub feature_bytes: f64,
}

impl UploadSizes {
    pub fn total(&self) -> f64 {
        self.model_bytes + self.feature_bytes
    }
}

/// Uplink transfer time (Eq 19): `T^co_m = (S_m + omega*d) / (b_m * B)`,
/// sizes in bytes, B in bits/s.
pub fn uplink_time(bytes: f64, frac: f64, bandwidth_bps: f64) -> f64 {
    assert!(frac > 0.0, "uplink_time with zero bandwidth fraction");
    bytes * 8.0 / (frac * bandwidth_bps)
}

/// Communication resource cost of one round (Eq 16):
/// `R_co = sum_m a_m b_m B p_c` — bandwidth-seconds priced at p_c.
/// With constraints (22a)/(22b) the selected fractions sum to 1, so a fully
/// subscribed round costs exactly `B * p_c`.
pub fn comm_cost(fracs: &[f64], bandwidth_bps: f64, p_c: f64) -> f64 {
    fracs.iter().sum::<f64>() * bandwidth_bps * p_c / 1e9 // per-Gbps unit
}

/// Communication resource cost with heterogeneous per-client rates (P2′):
/// `R_co = sum_m a_m f_m r_m p_c` where `r_m = share_m * B` is client m's
/// effective channel rate. NOT an algebraic rewrite of [`comm_cost`]: at
/// uniform rates the two sums associate differently, so callers on the
/// homogeneous path must keep calling `comm_cost` (the bitwise gate).
pub fn comm_cost_rates(fracs: &[f64], rates_bps: &[f64], p_c: f64) -> f64 {
    assert_eq!(fracs.len(), rates_bps.len());
    fracs.iter().zip(rates_bps).map(|(&f, &r)| f * r).sum::<f64>() * p_c / 1e9
}

/// Per-client transmit/compute energy pricing (P2′). Powers are derived per
/// RIC from its slice class — URLLC front-ends burn more joules per second
/// than mMTC — and the weight `rho_e` folds round energy into the P2
/// objective. `rho_e == 0` disables the term STRUCTURALLY (callers branch,
/// they never add `0.0 * x`), which is what keeps the homogeneous path
/// bitwise identical to the pre-P2′ solver.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// weight of the energy term in the P2′ objective (0 = off)
    pub rho_e: f64,
    /// base radio transmit power (W) while a client uploads
    pub p_tx: f64,
    /// base compute power (W) while a client trains
    pub p_cmp: f64,
}

impl EnergyModel {
    pub fn from_cfg(cfg: &SimConfig) -> Self {
        Self { rho_e: cfg.rho_e, p_tx: cfg.p_tx, p_cmp: cfg.p_cmp }
    }

    /// Whether the energy term participates in the objective at all.
    pub fn enabled(&self) -> bool {
        self.rho_e != 0.0
    }

    /// Slice-class power multiplier: eMBB 1.0, mMTC 1.25, URLLC 1.5.
    pub fn slice_weight(r: &RicProfile) -> f64 {
        1.0 + 0.25 * r.slice_class as f64
    }

    /// Effective transmit power (W) of RIC `r`.
    pub fn tx_power(&self, r: &RicProfile) -> f64 {
        self.p_tx * Self::slice_weight(r)
    }

    /// Effective compute power (W) of RIC `r`.
    pub fn cmp_power(&self, r: &RicProfile) -> f64 {
        self.p_cmp * Self::slice_weight(r)
    }
}

/// Round energy (J): `E_round = sum_m a_m (p_tx,m T^co_m + p_cmp,m T^cp_m)`.
/// The caller supplies per-selected-index uplink times and per-RIC compute
/// times so every framework prices exactly the transfers it actually makes.
pub fn round_energy(
    em: &EnergyModel,
    selected: &[&RicProfile],
    uplink_time_of: impl Fn(usize) -> f64,
    compute_time_of: impl Fn(&RicProfile) -> f64,
) -> f64 {
    selected
        .iter()
        .enumerate()
        .map(|(i, r)| em.tx_power(r) * uplink_time_of(i) + em.cmp_power(r) * compute_time_of(r))
        .sum()
}

/// Computation resource cost of one round (Eq 17):
/// `R_cp = sum_m a_m E (Q_C,m + Q_S,m) p_tr` (both sides billed — the
/// difference from O-RANFed/MCORANFed the paper calls out).
pub fn comp_cost(selected: &[&RicProfile], e: usize, p_tr: f64) -> f64 {
    selected
        .iter()
        .map(|r| e as f64 * (r.q_c + r.q_s) * p_tr)
        .sum()
}

/// One round's latency decomposition (Eq 18).
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundLatency {
    /// max_m (E*Q_C,m + T^co_m): client compute + uplink phase
    pub client_phase: f64,
    /// max_m (E*Q_S,m): server compute phase
    pub server_phase: f64,
    /// the slowest client's uplink time alone (feeds Algorithm 1's t_max)
    pub max_uplink: f64,
}

impl RoundLatency {
    pub fn total(&self) -> f64 {
        self.client_phase + self.server_phase
    }
}

/// Evaluate Eq 18 for a synchronous round: selected clients, their bandwidth
/// fractions, per-client upload sizes, and E local updates. `extra_uplink_per
/// _update` models frameworks whose transfers happen inside each local update
/// (vanilla SFL's per-batch smashed/gradient ping-pong) — SplitMe and the FL
/// baselines pass 0.
pub fn round_latency(
    selected: &[&RicProfile],
    fracs: &[f64],
    sizes: &[UploadSizes],
    e: usize,
    bandwidth_bps: f64,
    extra_uplink_per_update: f64,
    client_time_scale: f64,
) -> RoundLatency {
    assert_eq!(selected.len(), fracs.len());
    assert_eq!(selected.len(), sizes.len());
    let mut lat = RoundLatency::default();
    for ((r, &f), s) in selected.iter().zip(fracs).zip(sizes) {
        let per_round_bytes = s.total() + extra_uplink_per_update * e as f64;
        let t_co = uplink_time(per_round_bytes, f, bandwidth_bps);
        let t_client = e as f64 * r.q_c * client_time_scale + t_co;
        lat.client_phase = lat.client_phase.max(t_client);
        lat.server_phase = lat.server_phase.max(e as f64 * r.q_s);
        lat.max_uplink = lat.max_uplink.max(t_co);
    }
    lat
}

/// [`round_latency`] with heterogeneous per-client effective rates (P2′):
/// `rates_bps[i]` replaces the shared `bandwidth_bps` for selected client
/// `i`. The body keeps the exact expression shapes of the scalar version,
/// so with `rates_bps[i] == bandwidth_bps` for all i the result is bitwise
/// identical — division by an equal value is the same operation.
pub fn round_latency_rates(
    selected: &[&RicProfile],
    fracs: &[f64],
    sizes: &[UploadSizes],
    e: usize,
    rates_bps: &[f64],
    extra_uplink_per_update: f64,
    client_time_scale: f64,
) -> RoundLatency {
    assert_eq!(selected.len(), fracs.len());
    assert_eq!(selected.len(), sizes.len());
    assert_eq!(selected.len(), rates_bps.len());
    let mut lat = RoundLatency::default();
    for (((r, &f), s), &rate) in selected.iter().zip(fracs).zip(sizes).zip(rates_bps) {
        let per_round_bytes = s.total() + extra_uplink_per_update * e as f64;
        let t_co = uplink_time(per_round_bytes, f, rate);
        let t_client = e as f64 * r.q_c * client_time_scale + t_co;
        lat.client_phase = lat.client_phase.max(t_client);
        lat.server_phase = lat.server_phase.max(e as f64 * r.q_s);
        lat.max_uplink = lat.max_uplink.max(t_co);
    }
    lat
}

/// Total weighted round cost (Eq 20):
/// `rho (R_co + R_cp) + (1-rho) T_total`.
pub fn total_cost(rho: f64, r_co: f64, r_cp: f64, t_total: f64) -> f64 {
    rho * (r_co + r_cp) + (1.0 - rho) * t_total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        let mut cfg = SimConfig::commag();
        cfg.num_clients = 8;
        Topology::build(&cfg)
    }

    #[test]
    fn profiles_within_table_iii_ranges() {
        let t = topo();
        for r in &t.rics {
            assert!((0.34e-3..=0.46e-3).contains(&r.q_c), "{:?}", r);
            assert!((1.2e-3..=1.6e-3).contains(&r.q_s), "{:?}", r);
            assert!((50e-3..=100e-3).contains(&r.t_round), "{:?}", r);
            assert_eq!(r.slice_class, r.id % 3);
        }
    }

    #[test]
    fn topology_is_deterministic() {
        let a = topo();
        let b = topo();
        assert_eq!(a.rics[3].q_c, b.rics[3].q_c);
        assert_eq!(a.rics[5].t_round, b.rics[5].t_round);
    }

    #[test]
    fn most_slack_picks_the_least_bad_candidate() {
        let t = topo();
        let ct = |r: &RicProfile| 20.0 * (r.q_c + r.q_s);
        let best = t.most_slack(ct).unwrap();
        for r in &t.rics {
            assert!(r.t_round - ct(r) <= best.t_round - ct(best) + 1e-15);
        }
        let empty = Topology { rics: Vec::new(), bandwidth_bps: 1e9 };
        assert!(empty.most_slack(ct).is_none());
    }

    #[test]
    fn by_id_survives_candidate_filtering() {
        let t = topo();
        assert_eq!(t.by_id(5).unwrap().id, 5);
        assert!(t.by_id(99).is_none());
        // a filtered topology (scenario churn) keeps ids but shifts positions
        let filtered = Topology {
            rics: t.rics.iter().filter(|r| r.id % 2 == 1).cloned().collect(),
            bandwidth_bps: t.bandwidth_bps,
        };
        assert_eq!(filtered.by_id(5).unwrap().q_c, t.rics[5].q_c);
        assert!(filtered.by_id(4).is_none());
    }

    #[test]
    fn uplink_time_eq19() {
        // 1 MB at 20% of 1 Gbps = 8e6 bits / 2e8 bps = 40 ms
        let t = uplink_time(1e6, 0.2, 1e9);
        assert!((t - 0.04).abs() < 1e-12);
    }

    #[test]
    fn latency_is_max_over_clients() {
        let t = topo();
        let sel: Vec<&RicProfile> = t.rics.iter().take(3).collect();
        let sizes = vec![UploadSizes { model_bytes: 1e5, feature_bytes: 0.0 }; 3];
        let fr = vec![0.5, 0.25, 0.25];
        let lat = round_latency(&sel, &fr, &sizes, 10, 1e9, 0.0, 1.0);
        // client phase >= every individual client's time
        for ((r, &f), s) in sel.iter().zip(&fr).zip(&sizes) {
            let own = 10.0 * r.q_c + uplink_time(s.total(), f, 1e9);
            assert!(lat.client_phase >= own - 1e-15);
        }
        assert!(lat.server_phase >= 10.0 * sel[0].q_s - 1e-15);
        assert!(lat.total() > 0.0);
    }

    #[test]
    fn sfl_per_update_traffic_scales_with_e() {
        let t = topo();
        let sel: Vec<&RicProfile> = t.rics.iter().take(2).collect();
        let sizes = vec![UploadSizes::default(); 2];
        let fr = vec![0.5, 0.5];
        let l1 = round_latency(&sel, &fr, &sizes, 1, 1e9, 2e5, 1.0);
        let l10 = round_latency(&sel, &fr, &sizes, 10, 1e9, 2e5, 1.0);
        assert!(l10.max_uplink > 9.0 * l1.max_uplink);
    }

    #[test]
    fn cost_models() {
        let t = topo();
        let sel: Vec<&RicProfile> = t.rics.iter().take(4).collect();
        // fully-subscribed round: sum fracs = 1 -> R_co = B*p_c (in Gbps units)
        let rco = comm_cost(&[0.25; 4], 1e9, 1.0);
        assert!((rco - 1.0).abs() < 1e-12);
        let rcp = comp_cost(&sel, 10, 1.0);
        assert!(rcp > 0.0);
        let tot = total_cost(0.8, rco, rcp, 0.1);
        assert!((tot - (0.8 * (rco + rcp) + 0.2 * 0.1)).abs() < 1e-12);
    }

    #[test]
    fn latency_rates_at_uniform_rates_is_bitwise_scalar() {
        let t = topo();
        let sel: Vec<&RicProfile> = t.rics.iter().take(4).collect();
        let sizes = vec![UploadSizes { model_bytes: 3e5, feature_bytes: 1e4 }; 4];
        let fr = vec![0.4, 0.3, 0.2, 0.1];
        let a = round_latency(&sel, &fr, &sizes, 7, 1e9, 2e5, 1.3);
        let b = round_latency_rates(&sel, &fr, &sizes, 7, &[1e9; 4], 2e5, 1.3);
        assert_eq!(a.client_phase.to_bits(), b.client_phase.to_bits());
        assert_eq!(a.server_phase.to_bits(), b.server_phase.to_bits());
        assert_eq!(a.max_uplink.to_bits(), b.max_uplink.to_bits());
    }

    #[test]
    fn latency_rates_slow_client_dominates_uplink() {
        let t = topo();
        let sel: Vec<&RicProfile> = t.rics.iter().take(2).collect();
        let sizes = vec![UploadSizes { model_bytes: 1e6, feature_bytes: 0.0 }; 2];
        let fr = vec![0.5, 0.5];
        // client 1 parked on a 4x-slower RAT: its uplink alone sets max_uplink
        let lat = round_latency_rates(&sel, &fr, &sizes, 1, &[1e9, 0.25e9], 0.0, 1.0);
        let slow = uplink_time(1e6, 0.5, 0.25e9);
        assert_eq!(lat.max_uplink.to_bits(), slow.to_bits());
        assert!(lat.max_uplink > 3.9 * uplink_time(1e6, 0.5, 1e9));
    }

    #[test]
    fn comm_cost_rates_prices_each_client_at_its_own_rate() {
        // uniform rates agree with the scalar model to rounding
        let a = comm_cost(&[0.25; 4], 1e9, 1.0);
        let b = comm_cost_rates(&[0.25; 4], &[1e9; 4], 1.0);
        assert!((a - b).abs() < 1e-12);
        // a half-rate client pays half for the same fraction
        let het = comm_cost_rates(&[0.5, 0.5], &[1e9, 0.5e9], 1.0);
        assert!((het - 0.75).abs() < 1e-12);
    }

    #[test]
    fn energy_model_weights_slices_and_sums_round_energy() {
        let t = topo();
        let mut cfg = SimConfig::commag();
        cfg.rho_e = 0.5;
        let em = EnergyModel::from_cfg(&cfg);
        assert!(em.enabled());
        assert!(!EnergyModel { rho_e: 0.0, ..em }.enabled());
        // slice weights: eMBB 1.0 < mMTC 1.25 < URLLC 1.5
        assert_eq!(EnergyModel::slice_weight(&t.rics[0]), 1.0);
        assert_eq!(EnergyModel::slice_weight(&t.rics[1]), 1.25);
        assert_eq!(EnergyModel::slice_weight(&t.rics[2]), 1.5);
        assert!(em.tx_power(&t.rics[2]) > em.tx_power(&t.rics[0]));
        let sel: Vec<&RicProfile> = t.rics.iter().take(3).collect();
        let e = round_energy(&em, &sel, |_| 0.01, |r| 5.0 * r.q_c);
        let manual: f64 = sel
            .iter()
            .map(|r| em.tx_power(r) * 0.01 + em.cmp_power(r) * 5.0 * r.q_c)
            .sum();
        assert_eq!(e.to_bits(), manual.to_bits());
        assert!(e > 0.0);
    }
}

//! Deterministic fault injection: client dropouts, flaky uplinks, and crash
//! loops as per-`(round, client)` events derived from dedicated `RngPool`
//! substreams — the failure-side twin of the scenario engine
//! (`scenario::Scenario`). FedORA and O-RANFed (PAPERS.md) treat near-RT-RIC
//! unreliability and deadline misses as first-class selection/allocation
//! signals; this module supplies the reproducible failure traces those
//! mechanisms are exercised against.
//!
//! # Determinism & fairness contract (PERF.md §fault-model)
//!
//! [`Faults::round`] is a **pure function of `(seed, faults, M, round)`**:
//! every draw comes from `"faults/…"`-labeled substreams of the ROOT-seed
//! pool (never a per-framework pool) keyed by the round index, and
//! Markov-chain state replays from round 0 like the scenario chains.
//! Consequences:
//!
//! * all four frameworks of a paired comparison observe the **identical**
//!   fault trace, so the comparison stays paired under failure;
//! * no mutable state exists to be perturbed by `--jobs`/`--client-jobs`
//!   scheduling — the trace is bitwise reproducible at any worker count
//!   (tests/differential.rs gates this);
//! * the `none` preset (the default) draws **no randomness at all** and
//!   yields the all-clean event set, so the default path stays bitwise
//!   identical to the pre-fault-layer behavior.
//!
//! Event semantics (resolved against each framework's own selected set and
//! deadlines by [`RoundFaults::resolve`]):
//!
//! * **mid-round dropout** — the client finishes local compute, then
//!   vanishes before uploading (compute cost paid, nothing delivered, no
//!   retry possible);
//! * **flaky uplink** — upload attempts fail transiently; each retry waits
//!   an exponential backoff `retry_backoff_s · 2^(k-1)` and a retry whose
//!   cumulative backoff would blow the client's deadline slack is abandoned
//!   (deadline-budgeted retries);
//! * **crash loop** — a rounds-long crash episode (per-client Markov chain):
//!   dispatch to the client fails for the whole round, so it neither
//!   computes nor uploads.

use anyhow::{bail, Result};

use crate::config::SimConfig;
use crate::pop::{ChainMemo, PerClient};
use crate::sim::RngPool;

/// Named fault presets selectable via `SimConfig.faults` / `--faults`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// no faults (the default): bitwise identical to the pre-fault layer
    None,
    /// mid-round dropouts: clients vanish after local compute
    Dropout,
    /// transiently failing uploads, retried under the deadline budget
    FlakyUplink,
    /// rounds-long crash episodes: dispatch fails for the whole round
    CrashLoop,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Dropout => "dropout",
            Self::FlakyUplink => "flaky_uplink",
            Self::CrashLoop => "crash_loop",
        }
    }

    /// Canonical config spelling: parses back to `self` via `FromStr`.
    pub fn spec(&self) -> String {
        self.name().to_string()
    }

    /// Every preset, `none` first (the `experiment faults` matrix order —
    /// the `none` column is the control).
    pub fn all() -> [FaultKind; 4] {
        [Self::None, Self::Dropout, Self::FlakyUplink, Self::CrashLoop]
    }

    /// The presets that actually inject failures.
    pub fn active() -> [FaultKind; 3] {
        [Self::Dropout, Self::FlakyUplink, Self::CrashLoop]
    }
}

impl std::str::FromStr for FaultKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Ok(Self::None),
            "dropout" | "dropouts" => Ok(Self::Dropout),
            "flaky_uplink" | "flaky-uplink" | "flakyuplink" | "flaky" => Ok(Self::FlakyUplink),
            "crash_loop" | "crash-loop" | "crashloop" | "crash" => Ok(Self::CrashLoop),
            other => bail!("unknown fault preset {other:?} (none|dropout|flaky_uplink|crash_loop)"),
        }
    }
}

// --- preset parameters (documented in PERF.md §fault-model) ---

/// dropout: P(selected client vanishes after local compute) per round
const DROPOUT_P: f64 = 0.15;

/// flaky_uplink: P(one upload attempt fails), and the attempt cap — a
/// client whose first `FLAKY_MAX_ATTEMPTS` attempts all fail is lost this
/// round regardless of the remaining deadline budget
const FLAKY_P_FAIL: f64 = 0.35;
pub const FLAKY_MAX_ATTEMPTS: usize = 4;

/// crash_loop: P(healthy→crashed), P(crashed→healthy) per round
const CRASH_P_ON: f64 = 0.08;
const CRASH_P_OFF: f64 = 0.45;

/// The fault events of one round, indexed by client id. Produced by
/// [`Faults::round`]; identical across frameworks and parallelism knobs by
/// construction. `upload_attempts[m]` is the number of attempts client m's
/// upload needs to land (1 = clean, 0 = hopeless — more than
/// [`FLAKY_MAX_ATTEMPTS`] would be needed).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundFaults {
    pub round: usize,
    /// federation size M (event attributes are indexed by client id)
    pub m: usize,
    /// client finishes local compute, then never uploads (no retry)
    pub drop_after_compute: PerClient<bool>,
    /// attempts needed for the upload to land (1 = clean, 0 = hopeless)
    pub upload_attempts: PerClient<u8>,
    /// crash episode: dispatch fails all round (no compute, no upload)
    pub crashed: PerClient<bool>,
}

impl RoundFaults {
    /// The all-clean event set (what the `none` preset always returns) —
    /// O(1) in M via the broadcast representation.
    pub fn clean(round: usize, m: usize) -> Self {
        Self {
            round,
            m,
            drop_after_compute: PerClient::uniform(false),
            upload_attempts: PerClient::uniform(1),
            crashed: PerClient::uniform(false),
        }
    }

    /// True iff no client experiences any fault this round — O(1) on the
    /// broadcast (clean) representation.
    pub fn is_clean(&self) -> bool {
        self.drop_after_compute.all(self.m, |&d| !d)
            && self.upload_attempts.all(self.m, |&a| a == 1)
            && self.crashed.all(self.m, |&c| !c)
    }

    /// Resolve this round's events against one framework's selected set:
    /// which clients compute, how many upload attempts each performs under
    /// the exponential-backoff budget (`slack(m)` = seconds of deadline
    /// headroom client m has left for retries; retry k waits
    /// `backoff0 · 2^(k-1)`, and a retry whose cumulative backoff would
    /// exceed the slack is abandoned), and who survives to aggregation.
    pub fn resolve(
        &self,
        selected: &[usize],
        slack: impl Fn(usize) -> f64,
        backoff0: f64,
    ) -> FaultOutcome {
        let mut fates = Vec::with_capacity(selected.len());
        let mut retries = 0usize;
        let mut dropouts = 0usize;
        let mut max_backoff = 0f64;
        for &m in selected {
            let fate = if *self.crashed.get(m) {
                dropouts += 1;
                ClientFate { id: m, computed: false, attempts: 0, delivered: false, backoff: 0.0 }
            } else if *self.drop_after_compute.get(m) {
                dropouts += 1;
                ClientFate { id: m, computed: true, attempts: 0, delivered: false, backoff: 0.0 }
            } else {
                let needed = *self.upload_attempts.get(m) as usize;
                if needed == 1 {
                    ClientFate { id: m, computed: true, attempts: 1, delivered: true, backoff: 0.0 }
                } else {
                    let budget = slack(m).max(0.0);
                    // most retries the deadline budget can absorb: largest r
                    // with backoff0·(2^r - 1) <= budget, capped at the
                    // attempt cap (a hopeless upload stops retrying there)
                    let want = if needed == 0 { FLAKY_MAX_ATTEMPTS - 1 } else { needed - 1 };
                    let mut fit = 0usize;
                    let mut cum = 0f64;
                    while fit < want {
                        let wait = backoff0 * (1u64 << fit) as f64;
                        if cum + wait > budget {
                            break;
                        }
                        cum += wait;
                        fit += 1;
                    }
                    retries += fit;
                    max_backoff = max_backoff.max(cum);
                    let delivered = needed != 0 && fit == needed - 1;
                    if !delivered {
                        dropouts += 1;
                    }
                    ClientFate { id: m, computed: true, attempts: 1 + fit, delivered, backoff: cum }
                }
            };
            fates.push(fate);
        }
        FaultOutcome { fates, retries, dropouts, max_backoff }
    }
}

/// What happened to one selected client under this round's faults.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientFate {
    pub id: usize,
    /// ran its local training phase (false only for a crash episode)
    pub computed: bool,
    /// upload attempts actually performed (0 = never attempted)
    pub attempts: usize,
    /// the upload landed — this client's update reaches aggregation
    pub delivered: bool,
    /// total retry backoff this client waited (seconds)
    pub backoff: f64,
}

/// One framework's resolved fault outcome for one round.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultOutcome {
    /// one fate per selected client, in selected order
    pub fates: Vec<ClientFate>,
    /// upload retries performed across all clients (only ones that fit the
    /// deadline budget; the first attempt is not a retry)
    pub retries: usize,
    /// selected clients whose update never reached aggregation
    pub dropouts: usize,
    /// max per-client retry backoff (seconds) — uploads run in parallel, so
    /// the slowest client's backoff is what stretches the round
    pub max_backoff: f64,
}

impl FaultOutcome {
    /// Clients whose updates reached aggregation, in selected order.
    pub fn survivors(&self) -> Vec<usize> {
        self.fates.iter().filter(|f| f.delivered).map(|f| f.id).collect()
    }

    /// True iff every selected client computed, uploaded once, and landed —
    /// the fault-aware accounting then reduces bitwise to the clean one, and
    /// callers keep the historical (pre-fault-layer) code path.
    pub fn is_clean(&self) -> bool {
        self.fates.iter().all(|f| f.computed && f.delivered && f.attempts == 1)
    }
}

/// The fault process of one experiment: pure, cheap, shared. Built once per
/// `ExperimentContext` from the root `(seed, faults, M)` triple;
/// [`Faults::round`] derives any round's events on demand.
#[derive(Debug, Clone)]
pub struct Faults {
    kind: FaultKind,
    /// federation size M (event vectors are indexed by client id)
    m: usize,
    /// root-seed pool: fault streams live in the `"faults/…"` label
    /// namespace, disjoint from scenario/topology/init/framework streams
    pool: RngPool,
    /// reference (dense) path: cold crash-chain replay from round 0 and
    /// dense event representation (the pre-ISSUE-7 behavior)
    dense: bool,
    /// skip-ahead cache for the crash_loop per-client Markov chains
    memo_crash: ChainMemo<Vec<bool>>,
}

impl Faults {
    pub fn new(cfg: &SimConfig) -> Result<Self> {
        let mut f = Self::from_parts(cfg.faults.parse()?, cfg.seed, cfg.num_clients);
        f.dense = cfg.reference_path;
        Ok(f)
    }

    pub fn from_parts(kind: FaultKind, seed: u64, m: usize) -> Self {
        Self { kind, m, pool: RngPool::new(seed), dense: false, memo_crash: ChainMemo::new() }
    }

    /// Switch to (or away from) the reference dense path: cold chain
    /// replay, dense event representation. Used by the scale differential.
    pub fn set_dense(&mut self, dense: bool) {
        self.dense = dense;
    }

    pub fn kind(&self) -> FaultKind {
        self.kind
    }

    /// True for the `none` preset (callers may skip fault bookkeeping).
    pub fn is_none(&self) -> bool {
        self.kind == FaultKind::None
    }

    /// The fault events of `round`: a pure function of
    /// `(seed, faults, M, round)`. The `none` preset draws no randomness at
    /// all; `crash_loop` replays its per-client Markov chains from round 0
    /// (the scenario engine's statelessness trade, PERF.md §fault-model).
    pub fn round(&self, round: usize) -> RoundFaults {
        let mut ev = match self.kind {
            FaultKind::None => RoundFaults::clean(round, self.m),
            FaultKind::Dropout => self.dropout(round),
            FaultKind::FlakyUplink => self.flaky_uplink(round),
            FaultKind::CrashLoop => self.crash_loop(round),
        };
        if self.dense {
            ev.drop_after_compute.densify(self.m);
            ev.upload_attempts.densify(self.m);
            ev.crashed.densify(self.m);
        }
        ev
    }

    /// The full fault trace of `rounds` rounds (test/figure helper).
    pub fn trace(&self, rounds: usize) -> Vec<RoundFaults> {
        (0..rounds).map(|r| self.round(r)).collect()
    }

    /// Independent per-(round, client) Bernoulli dropouts.
    fn dropout(&self, round: usize) -> RoundFaults {
        let mut rng = self.pool.stream("faults/dropout", round as u64);
        let drops: Vec<bool> = (0..self.m).map(|_| rng.f64() < DROPOUT_P).collect();
        let mut ev = RoundFaults::clean(round, self.m);
        ev.drop_after_compute = PerClient::Dense(drops);
        ev
    }

    /// Per-(round, client) geometric attempt counts: each attempt fails
    /// independently with `FLAKY_P_FAIL`; a client whose first
    /// `FLAKY_MAX_ATTEMPTS` attempts all fail is hopeless (0) this round.
    fn flaky_uplink(&self, round: usize) -> RoundFaults {
        let mut rng = self.pool.stream("faults/flaky_uplink", round as u64);
        let attempts: Vec<u8> = (0..self.m)
            .map(|_| {
                let mut attempts = 0usize;
                loop {
                    attempts += 1;
                    if rng.f64() >= FLAKY_P_FAIL {
                        break;
                    }
                    if attempts == FLAKY_MAX_ATTEMPTS {
                        attempts = 0; // every attempt inside the cap failed
                        break;
                    }
                }
                attempts as u8
            })
            .collect();
        let mut ev = RoundFaults::clean(round, self.m);
        ev.upload_attempts = PerClient::Dense(attempts);
        ev
    }

    /// One transition of the per-client crash chains across round `r`
    /// (M sequential draws from the round-keyed stream).
    fn crash_step(&self, mut crashed: Vec<bool>, r: usize) -> Vec<bool> {
        let mut rng = self.pool.stream("faults/crash_loop", r as u64);
        for c in crashed.iter_mut() {
            let u = rng.f64();
            *c = if *c { u >= CRASH_P_OFF } else { u < CRASH_P_ON };
        }
        crashed
    }

    /// Per-client crash chain, starting all-healthy; defined by replay from
    /// round 0 and skip-ahead memoized (bitwise identical — every
    /// transition draws from a round-keyed stream).
    fn crash_loop(&self, round: usize) -> RoundFaults {
        let crashed = if self.dense {
            let mut c = vec![false; self.m];
            for r in 0..=round {
                c = self.crash_step(c, r);
            }
            c
        } else {
            self.memo_crash
                .state_at(round, || vec![false; self.m], |c, r| self.crash_step(c, r))
        };
        let mut ev = RoundFaults::clean(round, self.m);
        ev.crashed = PerClient::Dense(crashed);
        ev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn faults(kind: FaultKind, seed: u64, m: usize) -> Faults {
        Faults::from_parts(kind, seed, m)
    }

    #[test]
    fn names_parse_and_round_trip() {
        for kind in FaultKind::all() {
            let back: FaultKind = kind.name().parse().unwrap();
            assert_eq!(back, kind);
            assert_eq!(kind.spec().parse::<FaultKind>().unwrap(), kind);
        }
        assert!("nope".parse::<FaultKind>().is_err());
        assert_eq!("flaky-uplink".parse::<FaultKind>().unwrap(), FaultKind::FlakyUplink);
        assert_eq!("crash-loop".parse::<FaultKind>().unwrap(), FaultKind::CrashLoop);
        assert_eq!("off".parse::<FaultKind>().unwrap(), FaultKind::None);
    }

    #[test]
    fn none_preset_is_clean_and_draws_nothing() {
        // seed-independence is the observable proof that `none` never
        // touches the RNG: any two seeds yield the identical (clean) trace
        let a = faults(FaultKind::None, 1, 12).trace(40);
        let b = faults(FaultKind::None, 999, 12).trace(40);
        assert_eq!(a, b);
        for ev in &a {
            assert!(ev.is_clean());
        }
    }

    #[test]
    fn traces_are_pure_functions_of_seed_kind_round() {
        for kind in FaultKind::all() {
            let a = faults(kind, 42, 10).trace(30);
            let b = faults(kind, 42, 10).trace(30);
            assert_eq!(a, b, "{kind:?}: trace must be reproducible");
            // random access must agree with replay
            let f = faults(kind, 42, 10);
            assert_eq!(f.round(17), a[17], "{kind:?}: random access != replay");
            assert_eq!(f.round(3), a[3]);
        }
        for kind in FaultKind::active() {
            let a = faults(kind, 42, 10).trace(60);
            let b = faults(kind, 43, 10).trace(60);
            assert_ne!(a, b, "{kind:?}: seed must matter");
        }
    }

    #[test]
    fn dropout_only_sets_drop_flags() {
        let tr = faults(FaultKind::Dropout, 7, 20).trace(60);
        assert!(
            tr.iter().any(|e| e.drop_after_compute.iter(e.m).any(|&d| d)),
            "nobody dropped"
        );
        for e in &tr {
            assert!(e.upload_attempts.all(e.m, |&a| a == 1));
            assert!(e.crashed.all(e.m, |&c| !c));
        }
    }

    #[test]
    fn flaky_uplink_attempts_stay_in_range() {
        let tr = faults(FaultKind::FlakyUplink, 7, 20).trace(80);
        let mut saw_retry = false;
        let mut saw_clean = false;
        for e in &tr {
            assert!(e.drop_after_compute.all(e.m, |&d| !d));
            assert!(e.crashed.all(e.m, |&c| !c));
            for &a in e.upload_attempts.iter(e.m) {
                assert!((a as usize) <= FLAKY_MAX_ATTEMPTS);
                saw_retry |= a != 1;
                saw_clean |= a == 1;
            }
        }
        assert!(saw_retry, "no upload ever needed a retry");
        assert!(saw_clean, "no upload ever landed first try");
    }

    #[test]
    fn crash_episodes_persist_across_rounds() {
        let tr = faults(FaultKind::CrashLoop, 3, 30).trace(100);
        assert!(
            tr.iter().any(|e| e.crashed.iter(e.m).any(|&c| c)),
            "nobody ever crashed"
        );
        // the chain has memory: some episode spans >= 2 consecutive rounds
        let mut persisted = false;
        for w in tr.windows(2) {
            for m in 0..30 {
                persisted |= *w[0].crashed.get(m) && *w[1].crashed.get(m);
            }
        }
        assert!(persisted, "crash episodes never persisted");
    }

    #[test]
    fn memoized_crash_chain_matches_cold_replay() {
        let lazy = faults(FaultKind::CrashLoop, 11, 25);
        let mut dense = faults(FaultKind::CrashLoop, 11, 25);
        dense.set_dense(true);
        // mixed access pattern: sequential, backward, far-forward
        for r in [0usize, 1, 2, 7, 3, 8, 30, 31, 5, 30] {
            let a = lazy.round(r);
            let b = dense.round(r);
            assert_eq!(a, b, "round {r}: memoized != cold replay");
            // bitwise, not just semantic: both sides dense and elementwise equal
            assert_eq!(a.crashed.to_vec(25), b.crashed.to_vec(25));
        }
    }

    #[test]
    fn resolve_clean_events_is_clean() {
        let ev = RoundFaults::clean(0, 8);
        let out = ev.resolve(&[1, 3, 5], |_| 1.0, 0.05);
        assert!(out.is_clean());
        assert_eq!(out.survivors(), vec![1, 3, 5]);
        assert_eq!(out.retries, 0);
        assert_eq!(out.dropouts, 0);
        assert_eq!(out.max_backoff, 0.0);
    }

    #[test]
    fn resolve_dropout_pays_compute_but_never_delivers() {
        let mut ev = RoundFaults::clean(0, 4);
        ev.drop_after_compute.set(2, true, 4);
        let out = ev.resolve(&[0, 2], |_| 10.0, 0.05);
        assert_eq!(out.survivors(), vec![0]);
        assert_eq!(out.dropouts, 1);
        assert_eq!(out.retries, 0);
        let f2 = &out.fates[1];
        assert!(f2.computed && !f2.delivered);
        assert_eq!(f2.attempts, 0);
    }

    #[test]
    fn resolve_crash_skips_compute_entirely() {
        let mut ev = RoundFaults::clean(0, 4);
        ev.crashed.set(1, true, 4);
        let out = ev.resolve(&[0, 1, 3], |_| 10.0, 0.05);
        assert_eq!(out.survivors(), vec![0, 3]);
        assert_eq!(out.dropouts, 1);
        assert!(!out.fates[1].computed);
        assert_eq!(out.fates[1].attempts, 0);
    }

    #[test]
    fn resolve_budgets_retries_against_the_deadline() {
        let mut ev = RoundFaults::clean(0, 4);
        ev.upload_attempts.set(0, 3, 4); // needs 2 retries: backoff b + 2b = 3b
        let b = 0.05;
        // generous slack: both retries fit, client survives
        let out = ev.resolve(&[0], |_| 1.0, b);
        assert_eq!(out.survivors(), vec![0]);
        assert_eq!(out.retries, 2);
        assert!((out.max_backoff - 3.0 * b).abs() < 1e-12);
        // slack fits the first retry (b) but not the second (+2b): abandoned
        let out = ev.resolve(&[0], |_| 2.0 * b, b);
        assert!(out.survivors().is_empty());
        assert_eq!(out.dropouts, 1);
        assert_eq!(out.retries, 1);
        assert_eq!(out.fates[0].attempts, 2);
        assert!((out.max_backoff - b).abs() < 1e-12);
        // no slack at all: the retry is abandoned immediately
        let out = ev.resolve(&[0], |_| 0.0, b);
        assert_eq!(out.retries, 0);
        assert_eq!(out.fates[0].attempts, 1);
        assert_eq!(out.max_backoff, 0.0);
        // zero backoff: retries are free, so the budget never blocks them
        let out = ev.resolve(&[0], |_| 0.0, 0.0);
        assert_eq!(out.survivors(), vec![0]);
        assert_eq!(out.retries, 2);
    }

    #[test]
    fn resolve_hopeless_upload_stops_at_the_attempt_cap() {
        let mut ev = RoundFaults::clean(0, 2);
        ev.upload_attempts.set(0, 0, 2); // hopeless: every attempt in the cap fails
        let out = ev.resolve(&[0], |_| 1e9, 0.05);
        assert!(out.survivors().is_empty());
        assert_eq!(out.dropouts, 1);
        assert_eq!(out.fates[0].attempts, FLAKY_MAX_ATTEMPTS);
        assert_eq!(out.retries, FLAKY_MAX_ATTEMPTS - 1);
    }

    #[test]
    fn faults_new_reads_config_and_rejects_unknown() {
        let mut cfg = SimConfig::commag();
        assert!(Faults::new(&cfg).unwrap().is_none());
        cfg.faults = "dropout".into();
        assert_eq!(Faults::new(&cfg).unwrap().kind(), FaultKind::Dropout);
        cfg.faults = "bogus".into();
        assert!(Faults::new(&cfg).is_err());
    }
}

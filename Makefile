# Build-time entry points. `make artifacts` must run before any rust test,
# bench, or CLI invocation: it AOT-lowers the L2 JAX/Pallas functions to the
# HLO-text artifacts + manifest.json that rust/src/runtime loads.

.PHONY: artifacts tier1 bench

artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts/manifest.json

tier1:
	cd rust && cargo build --release && cargo test -q

bench: artifacts
	cd rust && cargo bench --bench perf_micro
